"""``bullion`` command-line tool: storage introspection + telemetry.

Run as ``python -m repro.cli <command>``:

* ``inspect PATH...`` — dump a shard's anatomy: footer sections, columns
  (kind/dtype/quantization), per-group layout, and with ``--pages`` every
  page's offset/size/rows/encoding, zone map, deletion vector, and sketch
  presence. Accepts files, shard directories, globs, and
  ``bullion://bucket/key`` object-store URIs (any dataset spec
  ``dataset()`` accepts).
* ``fsck PATH...`` — verify integrity: page checksums against the footer,
  the Merkle group/root bounds, deletion-vector soundness (extent bounds,
  compacted-page row accounting), zone-map consistency (decoded values
  inside recorded min/max), and sketch consistency (no false negatives).
  Exit code 0 = clean, 1 = content corruption found, 2 = unusable input
  (a torn or truncated shard the reader refuses to open, or a path that
  resolves to nothing). Checks gate on section presence, so v0
  (stat-less) through v3 (sketched) files all verify. ``--json`` emits a
  machine-readable report: per-shard, per-category check/failure counts
  with first-failure locations, plus the would-be exit code.
* ``log [PATH.jsonl]`` — pretty-print query-log records from a
  ``BULLION_QUERY_LOG`` JSONL sink, or ``--socket`` to pull the bounded
  ring from a live server.
* ``metrics`` — the metrics registry in Prometheus text format;
  ``--socket`` scrapes a live server, default renders this process's
  (mostly empty) registry.

Every check the fsck performs mirrors an invariant ``BullionWriter`` /
``deletion._rebuild_footer`` maintains — the test suite flips page bytes
and asserts the non-zero exit.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

import numpy as np

from .core.backend import open_shard
from .core.encodings import blob_encoding_name
from .core.footer import ColKind, PageType, Sec, ShardCorruptError, \
    read_footer
from .core.merkle import combine, page_hash
from .core.quantization import QUANT_DTYPE, QuantMode, QuantSpec, dequantize
from .core import pages as pages_mod
from .dataset.source import discover
from .obs.expose import prometheus_text
from .scan.sketch import canonical_u64
from .scan.stats import HAS_MINMAX, LIST_ELEMENTS

_U64_NONE = np.uint64(0xFFFFFFFFFFFFFFFF)
_COMPACTED = 0x80
_PTYPE_MASK = 0x7F


def _paths(specs: list[str]) -> list[str]:
    out: list[str] = []
    for spec in specs:
        out.extend(discover(spec))
    return out


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"


# ---------------------------------------------------------------------------
# inspect
# ---------------------------------------------------------------------------

def _quant_specs(fv) -> list[QuantSpec]:
    if not fv.has(Sec.QUANT_META):
        return [QuantSpec()] * fv.n_cols
    recs = fv.arr(Sec.QUANT_META, QUANT_DTYPE)
    return [QuantSpec.from_record(recs[c]) for c in range(fv.n_cols)]


def inspect_shard(path: str, *, pages: bool = False, out=None) -> None:
    out = sys.stdout if out is None else out
    fv, foot_off = read_footer(path)
    from .core.encodings.base import code_dtype
    print(f"{path}: bullion v{fv.format_version}  rows={fv.num_rows}  "
          f"cols={fv.n_cols}  groups={fv.n_groups}  pages={fv.n_pages}  "
          f"compliance=L{fv.compliance}  "
          f"file_checksum={fv.file_checksum:#018x}", file=out)
    secs = " ".join(
        f"{Sec(sid).name}({size}B)" if sid in Sec._value2member_map_
        else f"?{sid}({size}B)"
        for sid, (off, size) in sorted(fv._dir.items()))
    print(f"  sections: {secs}", file=out)
    props = fv.props()
    if props:
        print("  props: " + " ".join(f"{k}={v}"
                                     for k, v in sorted(props.items())),
              file=out)
    kinds = fv.arr(Sec.COL_KIND, np.uint8)
    dtypes = fv.arr(Sec.COL_DTYPE, np.uint8)
    logical = fv.arr(Sec.COL_LOGICAL, np.uint8)
    quants = _quant_specs(fv)
    csk = fv.arr(Sec.CHUNK_SKETCH, np.uint64) \
        if fv.has(Sec.CHUNK_SKETCH) else None
    names = fv.column_names()
    print(f"  {'col':<4}{'name':<16}{'kind':<10}{'dtype':<10}"
          f"{'logical':<10}{'quant':<14}sketched", file=out)
    for c, name in enumerate(names):
        q = quants[c]
        qs = QuantMode(q.mode).name.lower()
        if q.mode in (QuantMode.INT8_AFFINE, QuantMode.UINT8_AFFINE,
                      QuantMode.INT16_AFFINE):
            qs += f"(x{q.scale:g}+{q.zero:g})"
        sk = "-"
        if csk is not None:
            n_sk = int(np.sum(csk[c::fv.n_cols] != _U64_NONE))
            sk = f"{n_sk}/{fv.n_groups} chunk(s)"
        print(f"  {c:<4}{name:<16}{ColKind(int(kinds[c])).name.lower():<10}"
              f"{code_dtype(int(dtypes[c])).name:<10}"
              f"{code_dtype(int(logical[c])).name:<10}{qs:<14}{sk}",
              file=out)
    rows_per_group = fv.arr(Sec.ROWS_PER_GROUP, np.uint32)
    sizes = fv.arr(Sec.PAGE_SIZE, np.uint64)
    gps = fv.group_page_start()
    for g in range(fv.n_groups):
        s, e = int(gps[g]), int(gps[g + 1])
        print(f"  group {g}: rows={int(rows_per_group[g])} "
              f"pages=[{s},{e}) bytes={_fmt_bytes(int(sizes[s:e].sum()))}",
              file=out)
    if not pages:
        return
    offs = fv.arr(Sec.PAGE_OFFSET, np.uint64)
    prows = fv.arr(Sec.PAGE_ROWS, np.uint32)
    flags = fv.arr(Sec.PAGE_FLAGS, np.uint8)
    pstats = fv.page_stats()
    psk = fv.arr(Sec.PAGE_SKETCH, np.uint64) \
        if fv.has(Sec.PAGE_SKETCH) else None
    col_of = _page_columns(fv)
    print(f"  {'page':<6}{'col':<16}{'type':<14}{'rows':<7}{'offset':<10}"
          f"{'size':<9}{'enc':<17}{'zone map':<26}{'dv':<6}sketch",
          file=out)
    with open_shard(path) as h:
        for p in range(fv.n_pages):
            flag = int(flags[p])
            ptype = PageType(flag & _PTYPE_MASK).name.lower()
            if flag & _COMPACTED:
                ptype += "+compact"
            head = h.pread(int(offs[p]), min(int(sizes[p]), 64))
            try:
                enc = blob_encoding_name(head)
            except Exception:
                enc = "-"
            zm = "-"
            if pstats is not None and pstats[p]["flags"] & HAS_MINMAX:
                tag = "elems " if pstats[p]["flags"] & LIST_ELEMENTS else ""
                zm = (f"{tag}[{float(pstats[p]['min']):g}, "
                      f"{float(pstats[p]['max']):g}]")
            dv = fv.deletion_vector(p)
            dvs = str(int(dv.sum())) if dv is not None else "-"
            sk = "-"
            if psk is not None:
                sk = "yes" if psk[p] != _U64_NONE else "no"
            print(f"  {p:<6}{col_of[p][1]:<16}{ptype:<14}"
                  f"{int(prows[p]):<7}{int(offs[p]):<10}"
                  f"{int(sizes[p]):<9}{enc:<17}{zm:<26}{dvs:<6}{sk}",
                  file=out)


def _page_columns(fv) -> dict[int, tuple[int, str]]:
    """page ordinal -> (column index, column name) via the chunk index."""
    names = fv.column_names()
    out: dict[int, tuple[int, str]] = {}
    for g in range(fv.n_groups):
        for c in range(fv.n_cols):
            s, e = fv.chunk_pages(g, c)
            for p in range(s, e):
                out[p] = (c, names[c])
    return out


def cmd_inspect(args) -> int:
    try:
        paths = _paths(args.path)
    except (FileNotFoundError, ValueError) as e:
        print(f"bullion inspect: {e}", file=sys.stderr)
        return 2
    for i, path in enumerate(paths):
        if i:
            print()
        try:
            inspect_shard(path, pages=args.pages)
        except (OSError, ValueError) as e:
            print(f"{path}: unreadable: {e}", file=sys.stderr)
            return 2
    return 0


# ---------------------------------------------------------------------------
# fsck
# ---------------------------------------------------------------------------

class _Fsck:
    """One shard's verification pass; collects findings instead of raising
    so a single corrupt page doesn't hide the rest."""

    def __init__(self, path: str, *, max_errors: int = 50):
        self.path = path
        self.errors: list[str] = []
        self.checks = 0
        self.failures = 0
        self.unusable: Optional[str] = None
        self.cats: dict[str, dict] = {}
        self.max_errors = max_errors

    def _cat(self, cat: str) -> dict:
        return self.cats.setdefault(
            cat, {"checks": 0, "failed": 0, "first_failure": None})

    def fail(self, msg: str, cat: str = "structure") -> None:
        self.failures += 1
        d = self._cat(cat)
        d["failed"] += 1
        if d["first_failure"] is None:
            d["first_failure"] = msg
        if len(self.errors) < self.max_errors:
            self.errors.append(f"{self.path}: {msg}")

    def check(self, ok: bool, msg: str, cat: str = "structure") -> bool:
        self.checks += 1
        self._cat(cat)["checks"] += 1
        if not ok:
            self.fail(msg, cat=cat)
        return ok

    def report(self) -> dict:
        """Machine-readable summary for ``fsck --json``."""
        return {"path": self.path, "checks": self.checks,
                "failures": self.failures, "unusable": self.unusable,
                "categories": self.cats, "errors": list(self.errors)}

    def run(self) -> None:
        try:
            fv, foot_off = read_footer(self.path)
        except ShardCorruptError as e:
            # the reader refuses to open this file at all (torn write,
            # truncated footer, bad magic): unusable, not merely corrupt
            self.unusable = str(e)
            self.fail(f"unusable: {e}", cat="open")
            return
        except (OSError, ValueError) as e:
            self.fail(f"unreadable footer: {e}", cat="open")
            return
        offs = fv.arr(Sec.PAGE_OFFSET, np.uint64)
        sizes = fv.arr(Sec.PAGE_SIZE, np.uint64)
        prows = fv.arr(Sec.PAGE_ROWS, np.uint32)
        flags = fv.arr(Sec.PAGE_FLAGS, np.uint8)
        n_pages = fv.n_pages
        self.check(len(offs) == n_pages and len(sizes) == n_pages
                   and len(prows) == n_pages and len(flags) == n_pages,
                   f"page index sections disagree with META n_pages="
                   f"{n_pages}")

        # -- extents + checksums + Merkle bounds ---------------------------
        cksums = fv.arr(Sec.PAGE_CHECKSUM, np.uint64) \
            if fv.has(Sec.PAGE_CHECKSUM) else None
        raw_pages: dict[int, bytes] = {}
        with open_shard(self.path) as h:
            for p in range(n_pages):
                off, size = int(offs[p]), int(sizes[p])
                if not self.check(
                        0 <= off and off + size <= foot_off,
                        f"page {p}: extent [{off}, {off + size}) outside "
                        f"data region [0, {foot_off})", cat="extents"):
                    continue
                try:
                    blob = h.pread(off, size)
                except OSError as e:
                    self.fail(f"page {p}: unreadable: {e}", cat="extents")
                    continue
                raw_pages[p] = blob
                if cksums is not None:
                    self.check(
                        page_hash(blob) == int(cksums[p]),
                        f"page {p}: checksum mismatch (stored "
                        f"{int(cksums[p]):#018x}, computed "
                        f"{page_hash(blob):#018x})", cat="checksums")
        if cksums is not None and fv.has(Sec.GROUP_CHECKSUM):
            gsum = fv.arr(Sec.GROUP_CHECKSUM, np.uint64)
            gps = fv.group_page_start()
            groups_ok = True
            for g in range(fv.n_groups):
                want = combine(cksums[int(gps[g]):int(gps[g + 1])])
                if not self.check(
                        want == int(gsum[g]),
                        f"group {g}: Merkle checksum mismatch",
                        cat="merkle"):
                    groups_ok = False
            if groups_ok:
                self.check(combine(gsum) == fv.file_checksum,
                           "file Merkle root mismatch", cat="merkle")

        # -- deletion vectors ----------------------------------------------
        dv_data = len(fv.raw(Sec.DV_DATA)) if fv.has(Sec.DV_DATA) else 0
        dvs: dict[int, Optional[np.ndarray]] = {}
        if fv.has(Sec.DV_OFFSET):
            dvo = fv.arr(Sec.DV_OFFSET, np.uint64)
            dvl = fv.arr(Sec.DV_SIZE, np.uint32)
            for p in range(n_pages):
                if dvo[p] == _U64_NONE:
                    dvs[p] = None
                    continue
                need = (int(prows[p]) + 7) // 8
                if not self.check(
                        int(dvo[p]) + int(dvl[p]) <= dv_data
                        and int(dvl[p]) >= need,
                        f"page {p}: deletion vector extent "
                        f"[{int(dvo[p])}, +{int(dvl[p])}) unsound for "
                        f"{int(prows[p])} rows (DV_DATA {dv_data}B)",
                        cat="deletion_vectors"):
                    dvs[p] = None
                    continue
                dvs[p] = fv.deletion_vector(p)
        else:
            dvs = {p: None for p in range(n_pages)}
        for p in range(n_pages):
            if int(flags[p]) & _COMPACTED:
                self.check(dvs.get(p) is not None,
                           f"page {p}: COMPACTED flag without a deletion "
                           f"vector", cat="deletion_vectors")

        # -- decode + zone maps + sketches ---------------------------------
        kinds = fv.arr(Sec.COL_KIND, np.uint8)
        quants = _quant_specs(fv)
        pstats = fv.page_stats()
        cstats = fv.chunk_stats()
        col_of = _page_columns(fv)
        chunk_vals: dict[tuple[int, int], list[np.ndarray]] = {}
        for g in range(fv.n_groups):
            for c in range(fv.n_cols):
                s, e = fv.chunk_pages(g, c)
                for p in range(s, e):
                    if p not in raw_pages:
                        continue
                    vals = self._check_page(fv, g, c, p, raw_pages[p],
                                            int(flags[p]), int(prows[p]),
                                            dvs.get(p), kinds, quants,
                                            pstats)
                    if vals is not None:
                        chunk_vals.setdefault((g, c), []).append(vals)
        self._check_chunks(fv, chunk_vals, cstats, pstats, quants, kinds)

    def _decode(self, flag: int, blob: bytes):
        return pages_mod.decode_page(flag & _PTYPE_MASK, blob)

    def _check_page(self, fv, g: int, c: int, p: int, blob: bytes,
                    flag: int, rows: int, dv, kinds, quants, pstats
                    ) -> Optional[np.ndarray]:
        """Decode one page, verify its row accounting + zone map + sketch;
        returns the page's (dequantized, flattened) value array for the
        chunk-level checks, or None if the page didn't decode."""
        try:
            decoded = self._decode(flag, blob)
        except Exception as e:
            self.fail(f"page {p}: decode failed: {type(e).__name__}: {e}",
                      cat="decode")
            return None
        # row accounting: a compacted page physically stores only the
        # survivors; anything else stores the raw row count
        expect = rows
        if flag & _COMPACTED and dv is not None:
            expect = rows - int(dv.sum())
        self.check(len(decoded) == expect,
                   f"page {p}: decoded {len(decoded)} rows, footer says "
                   f"{expect} ({'compacted' if flag & _COMPACTED else 'raw'}"
                   f" of {rows})", cat="decode")
        kind = int(kinds[c])
        if kind == int(ColKind.STRING):
            return None                      # no numeric domain to verify
        if kind in (int(ColKind.SCALAR), int(ColKind.MEDIA_REF)):
            vals = np.asarray(decoded)
            if kind == int(ColKind.SCALAR) \
                    and quants[c].mode != QuantMode.NONE:
                vals = np.asarray(dequantize(vals, quants[c]))
        else:                                # list: element domain
            vals = np.concatenate([np.asarray(r) for r in decoded]) \
                if len(decoded) else np.zeros(0)
        finite = vals[np.isfinite(vals.astype(np.float64, copy=False))] \
            if vals.dtype.kind == "f" else vals
        if pstats is not None and pstats[p]["flags"] & HAS_MINMAX \
                and len(finite):
            lo, hi = float(pstats[p]["min"]), float(pstats[p]["max"])
            amin, amax = float(finite.min()), float(finite.max())
            self.check(amin >= lo and amax <= hi,
                       f"page {p}: zone map [{lo:g}, {hi:g}] excludes "
                       f"decoded range [{amin:g}, {amax:g}]",
                       cat="zone_maps")
        sk = fv.page_sketch(p)
        if sk is not None and len(finite):
            self._check_sketch(sk, finite, f"page {p}")
        return finite

    def _check_sketch(self, sk, vals: np.ndarray, what: str,
                      cap: int = 256) -> None:
        """A bloom sketch must never produce a false negative for a value
        the data actually holds."""
        uniq = np.unique(np.asarray(vals, np.float64))
        if len(uniq) > cap:
            idx = np.linspace(0, len(uniq) - 1, cap).astype(np.int64)
            uniq = uniq[idx]
        for v in uniq:
            self.checks += 1
            self._cat("sketches")["checks"] += 1
            if not sk.may_contain(float(v)):
                self.fail(f"{what}: sketch false negative for value "
                          f"{float(v):g} (key "
                          f"{int(canonical_u64(float(v)))})",
                          cat="sketches")
                return

    def _check_chunks(self, fv, chunk_vals, cstats, pstats, quants,
                      kinds) -> None:
        for (g, c), parts in chunk_vals.items():
            vals = np.concatenate(parts) if parts else np.zeros(0)
            if not len(vals):
                continue
            idx = g * fv.n_cols + c
            if cstats is not None and cstats[idx]["flags"] & HAS_MINMAX:
                lo, hi = float(cstats[idx]["min"]), float(cstats[idx]["max"])
                amin, amax = float(vals.min()), float(vals.max())
                self.check(
                    amin >= lo and amax <= hi,
                    f"chunk (g={g}, c={c}): zone map [{lo:g}, {hi:g}] "
                    f"excludes decoded range [{amin:g}, {amax:g}]",
                    cat="zone_maps")
            sk = fv.chunk_sketch(g, c)
            if sk is not None:
                self._check_sketch(sk, vals, f"chunk (g={g}, c={c})")


def cmd_fsck(args) -> int:
    as_json = getattr(args, "json", False)
    try:
        paths = _paths(args.path)
    except (FileNotFoundError, ValueError) as e:
        if as_json:
            print(json.dumps({"shards": [], "errors": 0, "unusable": 1,
                              "exit": 2, "error": str(e)}))
        else:
            print(f"bullion fsck: {e}", file=sys.stderr)
        return 2
    total_errors = 0
    unusable = 0
    reports: list[dict] = []
    for path in paths:
        f = _Fsck(path, max_errors=args.max_errors)
        f.run()
        reports.append(f.report())
        total_errors += f.failures
        unusable += 1 if f.unusable else 0
        if as_json:
            continue
        for err in f.errors:
            print(f"CORRUPT  {err}")
        if args.verbose or f.failures:
            state = "UNUSABLE" if f.unusable else \
                ("CORRUPT" if f.failures else "clean")
            print(f"{path}: {state} ({f.checks} check(s), "
                  f"{f.failures} error(s))")
    code = 2 if unusable else (1 if total_errors else 0)
    if as_json:
        print(json.dumps({"shards": reports, "errors": total_errors,
                          "unusable": unusable, "exit": code}, indent=2))
        return code
    if total_errors:
        print(f"bullion fsck: {total_errors} error(s) across "
              f"{len(paths)} shard(s)")
    elif args.verbose:
        print(f"bullion fsck: {len(paths)} shard(s) clean")
    return code


# ---------------------------------------------------------------------------
# log + metrics
# ---------------------------------------------------------------------------

def _format_record(r: dict) -> str:
    fp = (r.get("fingerprint") or "")[:12] or "-"
    hit = r.get("cache_hit")
    hit = "-" if hit is None else ("hit" if hit else "miss")
    wall = r.get("wall_seconds") or 0.0
    line = (f"{r.get('ts', 0):.3f} {r.get('origin', '?'):<10} "
            f"{(r.get('dataset') or '-'):<20} "
            f"{r.get('tenant', '-'):<10} {fp:<13}{hit:<5}"
            f"{r.get('rows', 0):>8} rows {wall * 1e3:>9.3f} ms  "
            f"{r.get('outcome', '?')}")
    if r.get("slow"):
        line += "  SLOW"
    if r.get("error"):
        line += f"  {r['error']}"
    return line


def cmd_log(args) -> int:
    records: list[dict] = []
    if args.socket:
        from .serve.client import ServeClient
        with ServeClient(args.socket) as cli:
            records = cli.server_log(args.n)
    elif args.path:
        try:
            with open(args.path) as f:
                for line in f:
                    if line.strip():
                        records.append(json.loads(line))
        except (OSError, ValueError) as e:
            print(f"bullion log: {args.path}: {e}", file=sys.stderr)
            return 2
        records = records[-args.n:]
    else:
        from .obs import querylog
        records = [r.to_dict() for r in querylog.LOG.tail(args.n)]
    if not records:
        print("no query-log records")
        return 0
    for r in records:
        print(_format_record(r))
    errors = sum(1 for r in records if r.get("outcome") != "ok")
    slow = sum(1 for r in records if r.get("slow"))
    print(f"-- {len(records)} record(s), {errors} error(s), "
          f"{slow} slow")
    return 0


def cmd_metrics(args) -> int:
    if args.socket:
        from .serve.client import ServeClient
        with ServeClient(args.socket) as cli:
            sys.stdout.write(cli.metrics_text())
    else:
        sys.stdout.write(prometheus_text())
    return 0


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="bullion", description="Bullion storage + telemetry tool")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("inspect", help="dump shard layout and metadata")
    p.add_argument("path", nargs="+",
                   help="shard file / dataset dir / glob")
    p.add_argument("--pages", action="store_true",
                   help="include the per-page table")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("fsck", help="verify shard integrity")
    p.add_argument("path", nargs="+",
                   help="shard file / dataset dir / glob")
    p.add_argument("--verbose", "-v", action="store_true")
    p.add_argument("--max-errors", type=int, default=50,
                   help="stop collecting per-shard findings after N")
    p.add_argument("--json", action="store_true",
                   help="machine-readable report: per-shard, per-category "
                        "check/failure counts + first failures")
    p.set_defaults(fn=cmd_fsck)

    p = sub.add_parser("log", help="pretty-print query-log records")
    p.add_argument("path", nargs="?",
                   help="BULLION_QUERY_LOG JSONL file")
    p.add_argument("--socket", help="pull from a live server socket")
    p.add_argument("-n", type=int, default=50, help="max records")
    p.set_defaults(fn=cmd_log)

    p = sub.add_parser("metrics",
                       help="metrics registry, Prometheus text format")
    p.add_argument("--socket", help="scrape a live server socket")
    p.set_defaults(fn=cmd_metrics)
    return ap


def main(argv: Optional[list[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
