"""Pallas TPU kernels for Bullion's compute hot-spots.

  bitunpack       — fixed-bit-width integer unpack (C6 FixedBitWidth/FOR
                    decode; the paper's SIMDFastBP128 analogue on the VPU)
  dequant         — fused per-feature dequantize + cast (C4 read path)
  filter          — conjunctive range filter for predicate pushdown (the
                    scan subsystem's batch row-survivor mask)
  flash_attention — blocked online-softmax attention (beyond-paper training
                    perf; the §Perf answer to vanilla attention's HBM traffic)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle). The TPU container
is CPU-only, so correctness is validated in interpret mode.
"""
