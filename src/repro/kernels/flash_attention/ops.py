"""jit'd public wrapper: [B, H, S, D] API with padding to kernel tiling."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import BLOCK_K, BLOCK_Q, flash_attention_pallas


def flash_attention(q, k, v, *, causal=True, window=0, interpret=True):
    """q,k,v: [B, H, S, D] -> [B, H, S, D]. S padded to 128, D padded to 128.

    Padded keys are masked out by the causal mask for padded queries and by
    zero-padding of K (their exp-scores underflow against real rows' max) —
    we additionally rely on cropping the padded queries from the output."""
    B, H, S, D = q.shape
    Sp = -(-S // BLOCK_Q) * BLOCK_Q
    Dp = -(-D // 128) * 128
    pad = ((0, 0), (0, 0), (0, Sp - S), (0, Dp - D))

    def prep(x):
        return jnp.pad(x, pad).reshape(B * H, Sp, Dp)

    qp, kp, vp = prep(q), prep(k), prep(v)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 kv_len=S, d_real=D, interpret=interpret)
    return out.reshape(B, H, Sp, Dp)[:, :, :S, :D]
