"""Pure-jnp oracle: vanilla (materialized-scores) attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: [B, H, S, D] -> [B, H, S, D]. Optional sliding window."""
    B, H, S, D = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window > 0:
        mask &= (pos[:, None] - pos[None, :]) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
