"""Pallas TPU kernel: blocked online-softmax (Flash) attention, forward.

Baseline vanilla attention materializes [S, S] f32 scores — the dominant HBM
term in the dry-run roofline for every dense train cell (EXPERIMENTS.md
§Roofline). This kernel streams K/V blocks through VMEM with running
(max, sum, acc) statistics so score tiles never leave VMEM.

Grid: (batch*heads, q_blocks, k_blocks) — the k axis is the innermost,
"revisiting" dimension: out/scratch blocks are indexed by (bh, q) only, so the
running statistics accumulate across k steps. Causal + sliding-window masking
prunes whole blocks via index arithmetic (fully masked blocks short-circuit).

MXU alignment: BLOCK_Q = BLOCK_K = 128, head_dim padded to a multiple of 128
by ops.py. Working set per program: q (128 x D) + k,v (128 x D each) + f32
scores tile (128 x 128) + acc (128 x D) — ~0.5 MB at D=128, far under VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, k_blocks: int,
            kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * BLOCK_Q
    k_start = ki * BLOCK_K

    def compute():
        q = q_ref[0].astype(jnp.float32)               # [BQ, D]
        k = k_ref[0].astype(jnp.float32)               # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 1)
        mask = k_pos < kv_len            # padded keys never participate
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # [BQ, BK]
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal or window > 0:
        # whole-block pruning: block is live iff some (q, k) pair is unmasked
        live = jnp.asarray(True)
        if causal:
            live &= q_start + BLOCK_Q - 1 >= k_start
        if window > 0:
            live &= (q_start - (k_start + BLOCK_K - 1)) < window
        pl.when(live)(compute)
    else:
        compute()

    @pl.when(ki == k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "kv_len", "d_real",
                                    "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           kv_len=None, d_real=None, interpret=True):
    """q,k,v: [BH, S, D] with S % BLOCK == 0, D % 128 == 0.
    kv_len: number of real (non-padded) keys; d_real: real head_dim for the
    softmax scale."""
    BH, S, D = q.shape
    scale = 1.0 / math.sqrt(d_real or D)
    kv_len = kv_len or S
    k_blocks = S // BLOCK_K
    grid = (BH, S // BLOCK_Q, k_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          k_blocks=k_blocks, kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, D), lambda b, q_, k_: (b, q_, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, q_, k_: (b, k_, 0)),
            pl.BlockSpec((1, BLOCK_K, D), lambda b, q_, k_: (b, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, D), lambda b, q_, k_: (b, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),   # running max
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),   # running sum
            pltpu.VMEM((BLOCK_Q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
