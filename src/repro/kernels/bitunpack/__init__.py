from .ops import bitunpack, pack_bp32
from .ref import bitunpack_ref, pack_bp32_ref

__all__ = ["bitunpack", "pack_bp32", "bitunpack_ref", "pack_bp32_ref"]
