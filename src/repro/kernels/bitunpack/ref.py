"""Pure-numpy/jnp oracle for the BP32 bit-planar unpack.

Layout ("BP32", the TPU-native adaptation of Bullion's FixedBitWidth): values
are grouped in 32s; plane word j of a group holds bit j of all 32 values
(bit i of word j == bit j of value i). A width-w column stores w uint32 words
per 32 values. This turns scalar-SIMD bit twiddling (the paper's CPU decode)
into lane-parallel VPU shifts — value i's bits live at lane position i across
the w plane words.
"""

from __future__ import annotations

import numpy as np


def pack_bp32_ref(values: np.ndarray, width: int) -> np.ndarray:
    """values: uint32[N] (N % 32 == 0, values < 2**width) -> uint32[N//32, w]."""
    assert values.ndim == 1 and len(values) % 32 == 0
    v = values.astype(np.uint32).reshape(-1, 32)
    planes = np.zeros((v.shape[0], width), np.uint32)
    for j in range(width):
        bits = (v >> np.uint32(j)) & np.uint32(1)          # [G, 32]
        planes[:, j] = (bits << np.arange(32, dtype=np.uint32)).sum(
            axis=1, dtype=np.uint32)
    return planes


def bitunpack_ref(planes: np.ndarray, width: int) -> np.ndarray:
    """planes: uint32[G, w] -> uint32[G*32]."""
    G = planes.shape[0]
    out = np.zeros((G, 32), np.uint32)
    lanes = np.arange(32, dtype=np.uint32)
    for j in range(width):
        bit = (planes[:, j:j + 1] >> lanes) & np.uint32(1)
        out |= bit << np.uint32(j)
    return out.reshape(-1)
