"""Pallas TPU kernel: BP32 bit-planar fixed-width unpack.

Grid tiles the group axis; each program unpacks a (GROUPS_PER_BLOCK, 32)
value tile from its (GROUPS_PER_BLOCK, w) plane words held in VMEM. The
inner loop over the w planes is unrolled at trace time (w is static), so the
body is pure lane-parallel shift/and/or on the VPU — the MXU is not involved,
matching the decode's integer character.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUPS_PER_BLOCK = 256          # 256 groups x 32 lanes = 8192 values per block


def _kernel(planes_ref, out_ref, *, width: int):
    planes = planes_ref[...]                        # [G_blk, w] uint32
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    acc = jnp.zeros((planes.shape[0], 32), jnp.uint32)
    for j in range(width):                          # static unroll
        word = planes[:, j:j + 1]                   # [G_blk, 1]
        bit = (word >> lanes) & jnp.uint32(1)
        acc = acc | (bit << jnp.uint32(j))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def bitunpack_pallas(planes: jax.Array, width: int,
                     interpret: bool = True) -> jax.Array:
    """planes: uint32[G, w] (G % GROUPS_PER_BLOCK == 0) -> uint32[G, 32]."""
    G = planes.shape[0]
    grid = (G // GROUPS_PER_BLOCK,)
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid=grid,
        in_specs=[pl.BlockSpec((GROUPS_PER_BLOCK, width), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((GROUPS_PER_BLOCK, 32), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, 32), jnp.uint32),
        interpret=interpret,
    )(planes)
