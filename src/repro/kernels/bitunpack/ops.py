"""jit'd public wrapper: pads ragged group counts, dispatches to the kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import GROUPS_PER_BLOCK, bitunpack_pallas
from .ref import pack_bp32_ref


def pack_bp32(values: np.ndarray, width: int) -> np.ndarray:
    """Host-side packing (write path runs on CPU in the storage layer)."""
    n = len(values)
    pad = (-n) % (32 * GROUPS_PER_BLOCK)
    v = np.concatenate([values.astype(np.uint32), np.zeros(pad, np.uint32)])
    return pack_bp32_ref(v, width)


def bitunpack(planes, width: int, n_values: int | None = None,
              interpret: bool = True):
    """Device-side unpack: uint32[G, w] -> uint32[n_values]."""
    out = bitunpack_pallas(jnp.asarray(planes), width, interpret=interpret)
    flat = out.reshape(-1)
    if n_values is not None:
        flat = flat[:n_values]
    return flat
