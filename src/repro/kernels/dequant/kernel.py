"""Pallas TPU kernel: fused per-feature dequantize + cast.

The storage quantization read path (§2.4): integer/bf16-bit columns arrive in
HBM straight from Bullion pages; the kernel fuses (dequantize, scale, cast)
into a single VMEM pass so the FP32 intermediate never exists — feeding
embeddings/features to the model at storage precision.

Grid tiles (rows, features); per-feature scale/zero tiles ride along the
feature axis only (index_map pins the row coordinate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 128


def _kernel(q_ref, scale_ref, zero_ref, out_ref, *, from_bf16_bits: bool,
            out_dtype):
    q = q_ref[...]
    if from_bf16_bits:
        f = jax.lax.bitcast_convert_type(q.astype(jnp.uint32) << 16,
                                         jnp.float32)
    else:
        f = q.astype(jnp.float32) * scale_ref[...][None, :] \
            + zero_ref[...][None, :]
    out_ref[...] = f.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret"))
def dequant_pallas(q, scale, zero, out_dtype=jnp.bfloat16, interpret=True):
    R, C = q.shape
    assert R % BLOCK_R == 0 and C % BLOCK_C == 0, (R, C)
    from_bf16 = q.dtype == jnp.uint16
    return pl.pallas_call(
        functools.partial(_kernel, from_bf16_bits=from_bf16,
                          out_dtype=out_dtype),
        grid=(R // BLOCK_R, C // BLOCK_C),
        in_specs=[
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda r, c: (r, c)),
            pl.BlockSpec((BLOCK_C,), lambda r, c: (c,)),
            pl.BlockSpec((BLOCK_C,), lambda r, c: (c,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda r, c: (r, c)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(q, scale, zero)
