"""Pure-jnp oracle for fused per-feature dequantization (Bullion §2.4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dequant_ref(q, scale, zero, out_dtype=jnp.bfloat16):
    """q: int8/uint8/int16[R, C] (affine) or uint16[R, C] (raw bf16 bits);
    scale/zero: f32[C] per-feature params. Returns out_dtype[R, C]."""
    if q.dtype == jnp.uint16:  # stored bf16 bit pattern -> float
        f = jax.lax.bitcast_convert_type(
            q.astype(jnp.uint32) << 16, jnp.float32)
        return f.astype(out_dtype)
    return (q.astype(jnp.float32) * scale[None, :] + zero[None, :]).astype(out_dtype)
