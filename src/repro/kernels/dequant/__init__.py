from .ops import dequant
from .ref import dequant_ref

__all__ = ["dequant", "dequant_ref"]
