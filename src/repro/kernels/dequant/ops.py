"""jit'd public wrapper with shape padding."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import BLOCK_C, BLOCK_R, dequant_pallas


def dequant(q, scale, zero, out_dtype=jnp.bfloat16, interpret=True):
    """q: [R, C] quantized column batch; scale/zero: [C]. Pads to kernel
    tiling and crops back."""
    q = jnp.asarray(q)
    R, C = q.shape
    Rp, Cp = -(-R // BLOCK_R) * BLOCK_R, -(-C // BLOCK_C) * BLOCK_C
    qp = jnp.pad(q, ((0, Rp - R), (0, Cp - C)))
    sp = jnp.pad(jnp.asarray(scale, jnp.float32), (0, Cp - C))
    zp = jnp.pad(jnp.asarray(zero, jnp.float32), (0, Cp - C))
    out = dequant_pallas(qp, sp, zp, out_dtype=out_dtype, interpret=interpret)
    return out[:R, :C]
