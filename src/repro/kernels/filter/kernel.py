"""Pallas TPU kernel: conjunctive range filter (predicate pushdown).

Grid tiles the row axis; each program holds a (C, BLOCK_N) tile of the
filter columns in VMEM plus the (C, 1) interval bounds, evaluates both bound
checks lane-parallel on the VPU, and AND-reduces across the (small, static)
column axis. Pure element-wise compare/select — the MXU is never involved,
matching the scan's integer/compare character. The uint8 survivor mask is
what the scanner feeds to compress/gather steps downstream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048                  # rows per program: 16 sublane rows x 128 lanes


def _kernel(cols_ref, lo_ref, hi_ref, out_ref):
    x = cols_ref[...]                               # [C, B] float32
    lo = lo_ref[...]                                # [C, 1]
    hi = hi_ref[...]
    ok = jnp.logical_and(x >= lo, x <= hi)          # NaN fails both -> False
    out_ref[...] = jnp.all(ok, axis=0, keepdims=True).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def range_mask_pallas(cols: jax.Array, lo: jax.Array, hi: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """cols: f32[C, N] (N % BLOCK_N == 0); lo, hi: f32[C] -> uint8[1, N]."""
    C, N = cols.shape
    grid = (N // BLOCK_N,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, BLOCK_N), lambda i: (0, i)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.uint8),
        interpret=interpret,
    )(cols, lo.reshape(C, 1), hi.reshape(C, 1))
