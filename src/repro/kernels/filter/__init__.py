from .ops import range_mask
from .ref import range_mask_ref

__all__ = ["range_mask", "range_mask_ref"]
