"""Pure-numpy oracle for the conjunctive range-filter kernel.

The kernel form of a pushed-down predicate is a per-column closed interval
(``scan.predicate.conjunctive_ranges``): a row survives iff every filter
column lies inside its interval. NaNs never survive (they fail both bound
checks), matching NumPy comparison semantics.
"""

from __future__ import annotations

import numpy as np


def range_mask_ref(cols: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """cols: f32[C, N]; lo, hi: f32[C] -> bool[N] conjunctive in-range mask."""
    cols = np.asarray(cols, np.float32)
    lo = np.asarray(lo, np.float32).reshape(-1, 1)
    hi = np.asarray(hi, np.float32).reshape(-1, 1)
    ok = (cols >= lo) & (cols <= hi)
    return ok.all(axis=0)
