"""jit'd public wrapper: pads ragged row counts, dispatches to the kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import BLOCK_N, range_mask_pallas


def range_mask(cols, lo, hi, n_values: int | None = None,
               interpret: bool = True) -> np.ndarray:
    """Conjunctive range filter: f32[C, N] columns -> bool[N] survivor mask.

    Pads the row axis to a BLOCK_N multiple (padding rows are sliced back
    off, so their mask value is irrelevant).
    """
    cols = np.atleast_2d(np.asarray(cols, np.float32))
    C, n = cols.shape
    if n_values is None:
        n_values = n
    pad = (-n) % BLOCK_N
    if pad:
        cols = np.concatenate([cols, np.zeros((C, pad), np.float32)], axis=1)
    out = range_mask_pallas(jnp.asarray(cols),
                            jnp.asarray(lo, jnp.float32),
                            jnp.asarray(hi, jnp.float32),
                            interpret=interpret)
    return np.asarray(out).reshape(-1)[:n_values].astype(bool)
