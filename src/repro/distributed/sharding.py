"""Distribution context: mesh + logical-axis rules + shape-aware helpers.

Parallelism map (production mesh (pod=2,) data=16, model=16):
  DP    — batch over ('pod', 'data')
  FSDP  — parameter/optimizer 'embed' dim over 'data' (ZeRO-3; GSPMD inserts
          per-layer all-gathers)
  TP    — 'heads' / 'ff' / 'vocab' over 'model' (Megatron)
  EP    — 'experts' over 'model' when divisible (else expert-TP over d_ff)
  SP    — long-context KV cache 'kv_seq' over 'data' when batch is
          unshardable (e.g. long_500k with global_batch=1)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.base import ShardingRules


def make_rules(mesh: Optional[Mesh], *, seq_sharded: bool = False,
               fsdp: bool = True, train_seq_sharded: bool = False) -> ShardingRules:
    """Build rules restricted to the axes this mesh actually has.

    ``train_seq_sharded`` enables Megatron-style sequence parallelism: the
    residual stream is sharded over 'model' between blocks, so per-layer
    activation checkpoints shrink by the TP degree (XLA materializes the
    all-gather before attention / reduce-scatter after, exactly Megatron-SP's
    collective pattern)."""
    if mesh is None:
        return ShardingRules(embed=None, heads=None, kv_heads=None, ff=None,
                             vocab=None, experts=None, lru=None, batch=None,
                             seq=None, kv_seq=None)
    names = set(mesh.axis_names)

    def ax(a):
        return a if a in names else None

    batch = tuple(a for a in ("pod", "data") if a in names) or None
    return ShardingRules(
        embed=ax("data") if fsdp else None,
        heads=ax("model"), kv_heads=ax("model"), ff=ax("model"),
        vocab=ax("model"), experts=ax("model"), lru=ax("model"),
        batch=batch,
        seq=ax("model") if train_seq_sharded else None,
        kv_seq=ax("data") if seq_sharded else None,
    )


@dataclasses.dataclass
class Dist:
    mesh: Optional[Mesh]
    rules: ShardingRules

    def batch_axes_for(self, b: int):
        """Largest prefix of the batch axes that divides b."""
        if self.mesh is None or self.rules.batch is None:
            return None
        axes = self.rules.batch if isinstance(self.rules.batch, tuple) \
            else (self.rules.batch,)
        chosen: list[str] = []
        prod = 1
        for a in axes:
            size = self.mesh.shape[a]
            if b % (prod * size) == 0:
                chosen.append(a)
                prod *= size
        if not chosen:
            return None
        return tuple(chosen) if len(chosen) > 1 else chosen[0]

    def sharding(self, spec: PartitionSpec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def make_dist(mesh: Optional[Mesh], **rule_kw) -> Dist:
    return Dist(mesh=mesh, rules=make_rules(mesh, **rule_kw))
