from .sharding import Dist, make_dist, make_rules

__all__ = ["Dist", "make_dist", "make_rules"]
