from .loader import BullionLoader
from .synthetic import write_lm_corpus, write_ads_table

__all__ = ["BullionLoader", "write_lm_corpus", "write_ads_table"]
