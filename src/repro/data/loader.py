"""Bullion-backed training input pipeline.

The loader is a streaming adapter over the lazy ``Dataset`` plan path: the
plan (projection to the token column, optional quality predicate) is built
and lowered once at construction — zone-map pruning decides the surviving
row groups up front — and each group is then read through the same
prune -> pread -> decode -> deletion-mask -> dequantize -> filter pipeline
every other surface uses. Work is split across data-parallel ranks by
*shard* when the dataset has at least one file per rank — each rank then
reads disjoint files, so distributed training never contends on a handle or
an OS page-cache line — and by row group otherwise (single-file datasets, or
fewer shards than ranks). Either way ranks see disjoint, contiguous ranges;
the quality-presorted layout keeps each rank's reads sequential. Host decode
overlaps device compute via a prefetch thread, ``prefetch=`` additionally
drives the pipelined I/O scheduler (``dataset.io``) so the next groups'
coalesced preads overlap the current group's decode, and the cursor (epoch,
group index) is checkpointable for exactly-once resume.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..dataset import dataset
from ..obs import metrics as _metrics
from ..obs import trace as _trace


@dataclass
class LoaderState:
    epoch: int = 0
    group: int = 0          # next row group (global index) to read


class BullionLoader:
    def __init__(self, path: str, *, batch_size: int, seq_len: int,
                 rank: int = 0, world: int = 1, prefetch: int = 2,
                 column: str = "tokens", seed: int = 0,
                 state: Optional[LoaderState] = None,
                 predicate=None):
        self.path = path
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.rank, self.world = rank, world
        self.column = column
        # batches-ahead bound for the consumer queue AND the read-ahead
        # depth of the I/O scheduler (prefetch > 1 pipelines preads)
        self.prefetch = max(1, int(prefetch))
        self.state = state or LoaderState()
        self.dataset = dataset(path).select([column])
        if predicate is not None:
            self.dataset = self.dataset.where(predicate)
        # planning is static per dataset: lower once (zone-map pruning picks
        # the surviving groups and credits pruned bytes), stream forever.
        # Groups are scheduled by *global* group index — shard-local index
        # offset by the groups of preceding shards — so a directory/glob
        # dataset streams every shard and a one-file cursor keeps the seed
        # checkpoint semantics (global index == file group index).
        src = self.dataset._source
        group_off = [0]
        for s in range(src.n_shards):
            group_off.append(group_off[-1] + src.footer(s).n_groups)
        self.n_groups = group_off[-1]
        self._tasks = {group_off[t.shard] + t.group: t
                       for t in self.dataset.tasks()}
        self._groups = sorted(self._tasks)
        # rank striping: across whole shards when every rank can own at
        # least one *surviving* file (disjoint handles, no shared page-cache
        # lines); across row groups otherwise (single file, fewer shards
        # than ranks, or zone-map pruning emptied too many shards — a rank
        # must never starve while others read). Shards are assigned by
        # position in the sorted surviving-shard list, which is identical on
        # every rank (same plan) and static across epochs and resumes.
        live = sorted({t.shard for t in self._tasks.values()})
        self._shard_rank = {s: i % world for i, s in enumerate(live)}
        self._stripe_shards = world > 1 and len(live) >= world
        self._tokens_per_batch = batch_size * (seq_len + 1)
        self._buf = np.zeros(0, np.int32)
        self._queue: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- group scheduling --------------------------------------------------------
    def _my_groups(self, epoch: int) -> list[int]:
        if self._stripe_shards:
            return [g for g in self._groups
                    if self._shard_rank[self._tasks[g].shard] == self.rank]
        return [g for i, g in enumerate(self._groups)
                if i % self.world == self.rank]

    def _make_scheduler(self, groups: list[int]):
        """Pipelined I/O over this rank's remaining groups for one epoch
        pass: the scheduler stages the next ``prefetch`` groups' coalesced
        preads while the current group decodes. None = serial reads."""
        if self.prefetch <= 1 or len(groups) <= 1:
            return None
        from ..dataset.io import IOScheduler
        opt = self.dataset.plan()
        cols = opt.prefetch_columns()
        if not cols:
            return None
        sched = IOScheduler(self.dataset._source,
                            [self._tasks[g] for g in groups],
                            columns=cols, io_depth=self.prefetch)
        sched.start()
        return sched

    def _read_group(self, g: int, reader=None) -> np.ndarray:
        task = self._tasks[g]
        sp = _trace.span("loader.read_group", cat="loader",
                         shard=task.shard, group=task.group, rank=self.rank)
        with sp:
            tbl = self.dataset.read_group(task.group, shard=task.shard,
                                          reader=reader)
            docs = tbl[self.column] if tbl is not None else []
            if len(docs) == 0:
                return np.zeros(0, np.int32)
            out = np.concatenate([np.asarray(d, np.int32) for d in docs]) \
                if isinstance(docs, list) else np.asarray(docs, np.int32)
            if sp.enabled:
                sp.set(tokens=int(len(out)))
            return out

    # -- iteration ------------------------------------------------------------------
    def _put(self, item) -> bool:
        """Bounded put that never deadlocks against close(): re-checks the
        stop flag instead of blocking forever on a full queue."""
        while not self._stop.is_set():
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self):
        try:
            while not self._stop.is_set():
                # resume skips already-consumed groups; the scheduler is
                # built over exactly the remaining ones, in read order
                mine = [g for g in self._my_groups(self.state.epoch)
                        if g >= self.state.group]
                sched = self._make_scheduler(mine)
                try:
                    for i, g in enumerate(mine):
                        reader = sched.reader_for(i) if sched is not None \
                            else None
                        self._buf = np.concatenate(
                            [self._buf, self._read_group(g, reader)])
                        while len(self._buf) >= self._tokens_per_batch:
                            # batch assembly: slice + reshape + copy out of
                            # the token buffer (the host-side cost between
                            # decode and the consumer queue)
                            with _trace.span("loader.batch", cat="loader",
                                             rank=self.rank,
                                             tokens=self._tokens_per_batch):
                                batch = self._buf[:self._tokens_per_batch] \
                                    .reshape(self.batch_size,
                                             self.seq_len + 1)
                                self._buf = \
                                    self._buf[self._tokens_per_batch:]
                                cursor = LoaderState(self.state.epoch, g + 1)
                                item = (batch.copy(), cursor)
                            _metrics.histogram(
                                "bullion.loader.queue_depth") \
                                .observe(self._queue.qsize())
                            if not self._put(item):
                                return
                        self.state.group = g + 1
                finally:
                    if sched is not None:
                        sched.close()
                self.state.epoch += 1
                self.state.group = 0
        except Exception as e:  # surface in consumer
            self._put(e)

    def __iter__(self) -> Iterator[tuple[np.ndarray, LoaderState]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._produce, daemon=True)
            self._thread.start()
        while True:
            item = self._queue.get()
            if isinstance(item, Exception):
                raise item
            yield item

    def close(self):
        # Order matters: signal stop first, then drain while joining — the
        # producer only blocks in bounded 0.1 s put() attempts, so draining
        # plus a timed join always converges (no full-queue deadlock).
        self._stop.set()
        if self._thread is not None:
            deadline = 20.0
            while self._thread.is_alive() and deadline > 0:
                try:
                    while True:
                        self._queue.get_nowait()
                except queue.Empty:
                    pass
                self._thread.join(timeout=0.2)
                deadline -= 0.2
            self._thread = None
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self.dataset.close()
