"""Bullion-backed training input pipeline.

Wide-table projection (§2.3) is the read primitive: the loader touches only
the projected columns' pages. Work is split by row group across data-parallel
ranks (disjoint, contiguous ranges — the quality-presorted layout keeps each
rank's reads sequential), host decode overlaps device compute via a prefetch
thread, and the cursor (epoch, group index) is checkpointable for
exactly-once resume.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from ..core.reader import BullionReader


@dataclass
class LoaderState:
    epoch: int = 0
    group: int = 0          # next row group (global index) to read


class BullionLoader:
    def __init__(self, path: str, *, batch_size: int, seq_len: int,
                 rank: int = 0, world: int = 1, prefetch: int = 2,
                 column: str = "tokens", seed: int = 0,
                 state: Optional[LoaderState] = None):
        self.path = path
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.rank, self.world = rank, world
        self.column = column
        self.state = state or LoaderState()
        self.reader = BullionReader(path)
        self.n_groups = self.reader.footer.n_groups
        self._tokens_per_batch = batch_size * (seq_len + 1)
        self._buf = np.zeros(0, np.int32)
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- group scheduling --------------------------------------------------------
    def _my_groups(self, epoch: int) -> list[int]:
        groups = list(range(self.n_groups))
        return [g for i, g in enumerate(groups) if i % self.world == self.rank]

    def _read_group(self, g: int) -> np.ndarray:
        tbl = next(iter(self.reader.project([self.column], groups=[g])))
        docs = tbl[self.column]
        return np.concatenate([np.asarray(d, np.int32) for d in docs]) \
            if isinstance(docs, list) else np.asarray(docs, np.int32)

    # -- iteration ------------------------------------------------------------------
    def _produce(self):
        try:
            while not self._stop.is_set():
                mine = self._my_groups(self.state.epoch)
                for g in mine:
                    if g < self.state.group:
                        continue  # resume skips already-consumed groups
                    self._buf = np.concatenate([self._buf, self._read_group(g)])
                    while len(self._buf) >= self._tokens_per_batch:
                        batch = self._buf[:self._tokens_per_batch] \
                            .reshape(self.batch_size, self.seq_len + 1)
                        self._buf = self._buf[self._tokens_per_batch:]
                        cursor = LoaderState(self.state.epoch, g + 1)
                        self._queue.put((batch.copy(), cursor))
                        if self._stop.is_set():
                            return
                    self.state.group = g + 1
                self.state.epoch += 1
                self.state.group = 0
        except Exception as e:  # surface in consumer
            self._queue.put(e)

    def __iter__(self) -> Iterator[tuple[np.ndarray, LoaderState]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._produce, daemon=True)
            self._thread.start()
        while True:
            item = self._queue.get()
            if isinstance(item, Exception):
                raise item
            yield item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self.reader.close()
