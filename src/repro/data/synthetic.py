"""Deterministic synthetic corpora written as Bullion tables.

``write_lm_corpus`` emits documents with Zipfian unigrams + injected n-gram
motifs, so a language model trained on it shows a real learning curve.
``write_ads_table`` reproduces the paper's Table 1 regime: a wide table of
sparse list<int64> features with sliding-window click sequences, quality
scores, and quantized float features.
"""

from __future__ import annotations

import os

import numpy as np

from ..core import BullionWriter, ColumnSpec, QuantMode, QuantSpec, quality_sort
from ..core.sparse_delta import SyntheticClickSeq


def _zipf_docs(rng, n_docs, vocab, doc_len, n_motifs=64, motif_len=8):
    """Documents with shared motifs: predictable structure for the LM."""
    motifs = rng.integers(2, vocab, (n_motifs, motif_len)).astype(np.int32)
    docs = []
    for _ in range(n_docs):
        base = (rng.zipf(1.3, doc_len).astype(np.int64) % (vocab - 2)) + 2
        base = base.astype(np.int32)
        # overwrite random spans with motifs (the learnable signal)
        for _ in range(doc_len // (motif_len * 4)):
            m = motifs[rng.integers(0, n_motifs)]
            pos = int(rng.integers(0, doc_len - motif_len))
            base[pos:pos + motif_len] = m
        docs.append(base)
    return docs


def write_lm_corpus(path: str, *, n_docs: int = 512, vocab: int = 256,
                    doc_len: int = 1024, seed: int = 0,
                    rows_per_group: int = 64) -> dict:
    rng = np.random.default_rng(seed)
    docs = _zipf_docs(rng, n_docs, vocab, doc_len)
    schema = [
        ColumnSpec("doc_id", "int64"),
        ColumnSpec("tokens", "list<int32>"),
        ColumnSpec("quality", "float32"),
        ColumnSpec("n_tokens", "int32"),
    ]
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      sort_udf=quality_sort("quality"),
                      props={"kind": "lm-corpus", "vocab": str(vocab)})
    w.write_table({
        "doc_id": np.arange(n_docs, dtype=np.int64),
        "tokens": docs,
        "quality": rng.random(n_docs).astype(np.float32),
        "n_tokens": np.full(n_docs, doc_len, np.int32),
    })
    return w.close()


def write_ads_table(path: str, *, n_rows: int = 8192, n_sparse: int = 32,
                    n_dense: int = 16, seq_len: int = 64, seed: int = 0,
                    rows_per_group: int = 2048) -> dict:
    """Wide ads-style table (Table 1 in miniature): sparse list<int64>
    features with sliding-window structure + BF16-quantized dense features."""
    rng = np.random.default_rng(seed)
    schema = [ColumnSpec("user_id", "int64"), ColumnSpec("ts", "int64")]
    table: dict = {
        "user_id": np.sort(rng.integers(0, n_rows // 8, n_rows)).astype(np.int64),
        "ts": np.arange(n_rows, dtype=np.int64),
    }
    gen = SyntheticClickSeq(seq_len=seq_len)
    for i in range(n_sparse):
        name = f"clk_seq_{i}"
        schema.append(ColumnSpec(name, "list<int64>", sparse_delta=True))
        table[name] = gen.generate(n_rows, seed=seed * 1000 + i)
    for i in range(n_dense):
        name = f"dense_{i}"
        schema.append(ColumnSpec(name, "float32",
                                 quant=QuantSpec(QuantMode.BF16)))
        table[name] = rng.normal(size=n_rows).astype(np.float32)
    schema.append(ColumnSpec("label", "int8"))
    table["label"] = (rng.random(n_rows) < 0.03).astype(np.int8)
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      props={"kind": "ads-table"})
    w.write_table(table)
    return w.close()
