"""Training step factory: loss -> grads -> AdamW, with optional microbatch
gradient accumulation (scan) and optional gradient compression."""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update


def make_train_step(model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    grad_transform: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 accumulates grads over a scan — smooths HBM peaks and
    gives the scheduler freedom to overlap per-microbatch collectives."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # [B, ...] -> [n, B/n, ...]
        def resplit(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
        mb = jax.tree.map(resplit, batch)

        def body(acc, one):
            loss, g = jax.value_and_grad(loss_fn)(params, one)
            return jax.tree.map(jnp.add, acc, (loss, g)), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(body, zero, mb)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, stats = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step
