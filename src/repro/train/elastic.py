"""Elastic scaling: re-lay a training state onto a different mesh.

Checkpoints are mesh-agnostic (host-gathered dense arrays). Growing or
shrinking the fleet = build the new mesh, derive the new NamedShardings from
the same logical-axis rules, and device_put the restored state — no format
migration. ``reshard_plan`` also reports which logical axes change their
physical partitioning, which the launcher logs on every elastic transition.

Straggler/failure handling at run time (documented policy, exercised in
tests at small scale):
  * the data loader hands out row-group ranges by rank; a failed rank's
    ranges are re-queued to survivors on the next epoch boundary
  * on persistent failure the launcher restarts from the latest checkpoint
    with the shrunken mesh (this module) — training resumes within one
    checkpoint interval
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from ..distributed import make_dist
from ..models.base import spec_tree


def shardings_for(decl, mesh: Mesh, **rule_kw):
    dist = make_dist(mesh, **rule_kw)
    specs = spec_tree(decl, dist.rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def reshard_plan(decl, old_mesh: Mesh, new_mesh: Mesh, **rule_kw) -> dict:
    """Summarize the partitioning delta between two meshes."""
    old = spec_tree(decl, make_dist(old_mesh, **rule_kw).rules, old_mesh)
    new = spec_tree(decl, make_dist(new_mesh, **rule_kw).rules, new_mesh)
    changed = []
    for (path, o), (_, n) in zip(
            jax.tree_util.tree_flatten_with_path(old)[0],
            jax.tree_util.tree_flatten_with_path(new)[0]):
        if o != n:
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            changed.append({"param": key, "old": str(o), "new": str(n)})
    return {"old_devices": old_mesh.size, "new_devices": new_mesh.size,
            "changed": changed, "n_changed": len(changed)}


def elastic_restore(manager, template, decl, new_mesh: Mesh, step=None,
                    **rule_kw) -> tuple[Any, dict]:
    """Restore a checkpoint onto `new_mesh` regardless of the mesh it was
    saved from."""
    shardings = shardings_for(decl, new_mesh, **rule_kw)
    # template and decl may cover different subtrees (params vs full state)
    state, manifest = manager.restore(template, step=step)
    params = jax.tree.map(lambda arr, sh: jax.device_put(arr, sh),
                          state, shardings)
    return params, manifest
