"""Fault-tolerant checkpointing.

Designed for 1000+-node operation:
  * atomic commits      — write to step_N.tmp/, fsync, rename; a crash mid-
                          write never corrupts the latest valid checkpoint
  * manifest            — step, data-pipeline cursor (exactly-once over the
                          corpus on restart), mesh shape, param tree digest
  * async saves         — serialization happens on a background thread so the
                          train loop only blocks on device->host transfer
  * keep-N GC           — bounded disk usage
  * auto-resume         — restore() finds the latest *complete* checkpoint;
                          partial directories are ignored and reaped
  * elastic restore     — checkpoints are stored unsharded (host gathers);
                          restoring onto a different mesh re-shards via the
                          target's NamedShardings (see elastic.py)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True
    _thread: Optional[threading.Thread] = field(default=None, repr=False)
    _error: Optional[BaseException] = field(default=None, repr=False)

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state: dict, extra: Optional[dict] = None) -> None:
        """state: pytree dict (params/opt_state/...). Device->host transfer is
        synchronous; disk serialization is async (if enabled)."""
        self.wait()  # one in-flight save at a time; surfaces prior errors
        host_flat = {k: v for k, v in _flatten(state).items()}
        manifest = {"step": step, "time": time.time(),
                    "n_arrays": len(host_flat), **(extra or {})}

        def commit():
            tmp = os.path.join(self.directory, f"step_{step:09d}.tmp")
            final = os.path.join(self.directory, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host_flat)
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_save:
            def run():
                try:
                    commit()
                except BaseException as e:  # surfaced on next save/wait
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            commit()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore -----------------------------------------------------------------
    def _complete_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            path = os.path.join(self.directory, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(path, MANIFEST)):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> tuple[Any, dict]:
        """Restore into the structure of `template`. With `shardings` (a
        matching pytree of NamedSharding), arrays go straight to their target
        layout — this is the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        state = _unflatten(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), state, shardings)
        return state, manifest

    # -- GC ------------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self._complete_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
        # reap stale tmp dirs (crashed writers)
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                full = os.path.join(self.directory, name)
                if time.time() - os.path.getmtime(full) > 300:
                    shutil.rmtree(full, ignore_errors=True)
