"""AdamW + warmup-cosine schedule + global-norm clipping, in pure JAX.
Optimizer state is a pytree mirroring params (m, v) — it inherits the params'
sharding (FSDP shards optimizer state too, ZeRO-style)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
