"""Gradient compression for cross-pod data parallelism.

Two composable transforms (applied before the optimizer):
  * bf16_grads      — cast gradients to bf16 before the (GSPMD-inserted)
                      all-reduce; halves DCI bytes on the 'pod' axis.
  * topk_compress   — per-tensor magnitude top-k sparsification with error
                      feedback (the residual is carried to the next step),
                      the classic deep-gradient-compression recipe.

Error-feedback state lives beside the optimizer state and checkpoints with it.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def bf16_grads(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def topk_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_compress(grads, residual, fraction: float = 0.01):
    """Keep the top-`fraction` magnitude entries of (grad + residual) per
    tensor; the rest feeds back into the residual. Returns (sparse_grads,
    new_residual)."""

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        flat = acc.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(acc) >= thresh
        sent = jnp.where(mask, acc, 0.0)
        return sent.astype(g.dtype), acc - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
