"""chameleon-34b [vlm]: 48L, d_model=8192, 64H (GQA kv=8), d_ff=22016,
vocab=65536 (early fusion: VQ image tokens share the text vocab), qk-norm.
Image tokenizer frontend STUBBED: inputs are token ids. [arXiv:2405.09818]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    segments=((("full:swiglu",), 48),),
    qk_norm=True, frontend="vlm_stub",
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        segments=((("full:swiglu",), 2),))
