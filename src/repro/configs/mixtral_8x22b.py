"""mixtral-8x22b [moe]: 56L, d_model=6144, 48H (GQA kv=8), expert d_ff=16384,
vocab=32768, 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    segments=((("window:moe",), 56),),
    window=4096,
    n_experts=8, top_k=2, moe_ff=16384,
    sub_quadratic=True,    # SWA rolling KV -> bounded decode cache
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        window=8, n_experts=4, top_k=2, moe_ff=64,
        segments=((("window:moe",), 2),))
