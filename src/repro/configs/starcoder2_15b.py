"""starcoder2-15b [dense]: 40L, d_model=6144, 48H (GQA kv=4), d_ff=24576,
vocab=49152, RoPE, LayerNorm + GELU MLP. [arXiv:2402.19173]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152,
    segments=((("full:gelu",), 40),),
    norm="layernorm",
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        segments=((("full:gelu",), 2),))
