"""deepseek-moe-16b [moe]: 28L, d_model=2048, 16H (MHA kv=16), vocab=102400.
Layer 0 is dense (d_ff=10944); layers 1..27 are fine-grained MoE with 64
routed experts (d_ff=1408, top-6) + 2 shared experts. [arXiv:2401.06066]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab=102400,
    segments=((("full:swiglu",), 1), (("full:moe",), 27)),
    n_experts=64, top_k=6, moe_ff=1408, n_shared=2,
    sub_quadratic=False,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        n_experts=8, top_k=2, moe_ff=32, n_shared=1,
        segments=((("full:swiglu",), 1), (("full:moe",), 2)))
