"""rwkv6-7b [ssm]: 32L, d_model=4096, attention-free (WKV6 data-dependent
decay), d_ff=14336, vocab=65536. [arXiv:2404.05892] head_size=64 -> 64 heads."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    segments=((("rwkv:none",), 32),),
    norm="layernorm",
    sub_quadratic=True,                        # O(1) state decode
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        segments=((("rwkv:none",), 2),))
