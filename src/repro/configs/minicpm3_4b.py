"""minicpm3-4b [dense]: 62L, d_model=2560, 40H (MHA kv=40), d_ff=6400,
vocab=73448, Multi-head Latent Attention (MLA). [hf:openbmb/MiniCPM3-4B]"""

from ..models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=6400, vocab=73448,
    segments=((("mla:swiglu",), 62),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    sub_quadratic=False,   # full attention (MLA compresses the cache, but the
                           # family is quadratic-prefill -> long_500k skipped)
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        segments=((("mla:swiglu",), 2),),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16))
