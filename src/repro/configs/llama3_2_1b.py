"""llama3.2-1b [dense]: 16L, d_model=2048, 32H (GQA kv=8), d_ff=8192,
vocab=128256, RoPE theta 5e5, tied embeddings. [hf:meta-llama/Llama-3.2-1B]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256,
    segments=((("full:swiglu",), 16),),
    rope_theta=500000.0, tie_embeddings=True,
    sub_quadratic=False,                       # pure full attention
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        segments=((("full:swiglu",), 2),))
