"""Assigned-architecture registry: ``get(name)`` -> full ModelConfig,
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests."""

from __future__ import annotations

from importlib import import_module

from ..models.config import ModelConfig, SHAPES, ShapeConfig

ARCHS = (
    "whisper_base", "rwkv6_7b", "llama3_2_1b", "gemma3_12b", "minicpm3_4b",
    "starcoder2_15b", "mixtral_8x22b", "deepseek_moe_16b",
    "recurrentgemma_9b", "chameleon_34b",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "whisper-base": "whisper_base", "rwkv6-7b": "rwkv6_7b",
    "llama3.2-1b": "llama3_2_1b", "gemma3-12b": "gemma3_12b",
    "minicpm3-4b": "minicpm3_4b", "starcoder2-15b": "starcoder2_15b",
    "mixtral-8x22b": "mixtral_8x22b", "deepseek-moe-16b": "deepseek_moe_16b",
    "recurrentgemma-9b": "recurrentgemma_9b", "chameleon-34b": "chameleon_34b",
})


def _module(name: str):
    key = _ALIAS.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ALIAS)}")
    return import_module(f".{key}", __package__)


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke()


__all__ = ["ARCHS", "SHAPES", "ShapeConfig", "get", "get_smoke"]
