"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H, d_ff=2048,
vocab=51865. Enc-dec; conv/mel frontend STUBBED (precomputed frame embeds).
[arXiv:2212.04356]"""

from ..models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865,
    segments=((("full:gelu",), 6),),          # decoder depth
    encoder=EncoderConfig(n_layers=6, seq=1500, d_input=512),
    norm="layernorm", frontend="audio_stub", tie_embeddings=True,
    sub_quadratic=False,                       # full attention -> skip long_500k
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        segments=((("full:gelu",), 2),),
        encoder=EncoderConfig(n_layers=2, seq=16, d_input=64))
