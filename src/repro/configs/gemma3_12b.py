"""gemma3-12b [dense]: 48L, d_model=3840, 16H (GQA kv=8, head_dim=256),
d_ff=15360, vocab=262144, 5:1 local(1k window):global interleave, qk-norm,
sqrt(d) embed scaling, tied embeddings. [hf:google/gemma-3-*]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    segments=(((("local:swiglu",) * 5 + ("global:swiglu",)), 8),),
    window=1024, qk_norm=True, embed_scale=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
    sub_quadratic=True,   # 5/6 layers are 1k-window; long_500k decode runs
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        window=8,
        segments=(((("local:swiglu",) * 2 + ("global:swiglu",)), 2),))
