"""recurrentgemma-9b [hybrid]: 38 blocks in a (RG-LRU, RG-LRU, local-attn)
pattern (1 attention : 2 recurrent) + 2 trailing recurrent blocks; d_model=4096,
16H (MQA kv=1, head_dim=256), d_ff=12288, vocab=256000, window=2048.
[arXiv:2402.19427]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    segments=(
        (("rglru:swiglu", "rglru:swiglu", "local:swiglu"), 12),
        (("rglru:swiglu",), 2),
    ),
    window=2048, lru_width=4096, conv_width=4, embed_scale=True,
    tie_embeddings=True,
    sub_quadratic=True,    # recurrent state + bounded local window
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=256,
        window=8, lru_width=64,
        segments=((("rglru:swiglu", "rglru:swiglu", "local:swiglu"), 1),
                  (("rglru:swiglu",), 1)))
